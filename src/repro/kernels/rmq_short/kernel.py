"""Pallas TPU kernel: short-span RMQ via a direct two-chunk level-0 scan.

The full query kernel (``repro.kernels.rmq_scan``) pays a *constant*
``2c(L-1) + ct`` scanned lanes per query — the branch-free walk's price
for range-size independence.  For the paper's "small" range class that
constant is almost all waste: a query spanning at most two aligned
chunks (``r // c - l // c <= 1``, the engine planner's SHORT predicate)
is answered exactly by the two level-0 chunks it touches.  This kernel
skips the hierarchy entirely:

* bounds for a ``qb``-query tile arrive in SMEM via one block DMA (the
  WLQ analogue, same as rmq_scan);
* per query, the two aligned chunks ``floor(l/c)`` and ``floor(l/c)+1``
  are DMA'd HBM→VMEM into a double buffer, prefetching query ``i+1``'s
  chunks while the VPU scans query ``i``;
* one masked min over the ``(2, c)`` window produces the value, and —
  because level 0 is the original array — the leftmost-minimum
  *position* falls out of the same scan as the masked index min.  No
  ``upper_pos`` planes, so ``RMQ_index`` works even on value-only
  builds.

The anchor is clamped to ``capacity - 2c`` (mirrors the ref oracle), so
the kernel requires ``capacity >= 2c``; ``ops.py`` falls back to the ref
below that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import HierarchyPlan

from repro.core.constants import POS_INF_I32 as _POS_INF_I32

DEFAULT_QUERY_BLOCK = 256


def _rmq_short_kernel(
    # inputs
    l_ref,       # SMEM (qb,) i32
    r_ref,       # SMEM (qb,) i32
    base_hbm,    # ANY  (capacity,) values, stays in HBM
    # outputs
    out_ref,     # SMEM (qb,) f32
    out_pos_ref, # SMEM (qb,) i32 or None (closure decides)
    # scratch
    win_ref,     # VMEM (2, 2, c) double-buffered two-chunk windows
    sems,        # DMA semaphores (2, 2)
    *,
    plan: HierarchyPlan,
    qb: int,
    track_pos: bool,
):
    c = plan.c
    cap = plan.capacity
    lane = jax.lax.broadcasted_iota(jnp.int32, (2, c), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (2, c), 0)

    def anchor_of(i):
        l = l_ref[i]
        return jnp.clip((l // c) * c, 0, max(cap - 2 * c, 0))

    def issue(i, slot):
        a = anchor_of(i)
        for side in range(2):
            pltpu.make_async_copy(
                base_hbm.at[pl.ds(a + side * c, c)],
                win_ref.at[slot, side],
                sems.at[slot, side],
            ).start()

    def wait(i, slot):
        a = anchor_of(i)
        for side in range(2):
            pltpu.make_async_copy(
                base_hbm.at[pl.ds(a + side * c, c)],
                win_ref.at[slot, side],
                sems.at[slot, side],
            ).wait()

    issue(0, 0)

    def body(i, _):
        slot = jax.lax.rem(i, 2)
        wait(i, slot)

        @pl.when(i + 1 < qb)
        def _prefetch():
            issue(i + 1, 1 - slot)

        l = l_ref[i]
        r = r_ref[i]
        a = anchor_of(i)
        idx = a + row * c + lane              # absolute level-0 indices
        mask = (idx >= l) & (idx <= r)
        masked = jnp.where(mask, win_ref[slot], jnp.inf)
        m = jnp.min(masked)
        out_ref[i] = m
        if track_pos:
            cand = jnp.where(mask & (masked == m), idx, _POS_INF_I32)
            out_pos_ref[i] = jnp.min(cand)
        return 0

    jax.lax.fori_loop(0, qb, body, 0)


def rmq_short_pallas(
    base: jax.Array,
    ls: jax.Array,
    rs: jax.Array,
    plan: HierarchyPlan,
    qb: int = DEFAULT_QUERY_BLOCK,
    track_pos: bool = False,
    interpret: bool = False,
):
    """Launch the short-span kernel.  ``ls.shape[0]`` must be qb-aligned.

    Returns ``(values, positions)``; positions are INT32_MAX when
    ``track_pos=False``.  Requires ``plan.capacity >= 2 * plan.c``.
    """
    m = ls.shape[0]
    assert m % qb == 0, (m, qb)
    assert plan.capacity >= 2 * plan.c, (plan.capacity, plan.c)
    grid = (m // qb,)
    c = plan.c

    kernel = functools.partial(
        _rmq_short_kernel, plan=plan, qb=qb, track_pos=track_pos
    )

    in_specs = [
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pl.ANY),       # base stays in HBM
    ]
    out_specs = [
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
    ]
    out_shape = [jax.ShapeDtypeStruct((m,), base.dtype)]

    if track_pos:
        out_specs.append(
            pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM)
        )
        out_shape.append(jax.ShapeDtypeStruct((m,), jnp.int32))

        def kern(l_ref, r_ref, base_h, o_ref, opos_ref, win, sems):
            kernel(l_ref, r_ref, base_h, o_ref, opos_ref, win, sems)
    else:

        def kern(l_ref, r_ref, base_h, o_ref, win, sems):
            kernel(l_ref, r_ref, base_h, o_ref, None, win, sems)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((2, 2, c), base.dtype),   # [slot][chunk][c] dbl-buf
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(ls, rs, base)
    if track_pos:
        return out[0], out[1]
    return out[0], None
