"""Pure-jnp oracle for the fused query kernel.

The fused kernel's algorithm *is* the branch-free walk of
``kernels/rmq_scan`` — the fusion is in the execution shape (the whole
mixed batch, both output planes, one launch), not the algebra — so the
oracle delegates to the shared branch-free reference instead of keeping
a drifting copy (same policy as ``hierarchy_fused/ref.py``).  The one
addition is the dual-plane contract: a single call returns values AND
leftmost-tie positions, which is what lets a batch mixing ``RMQ_value``
and ``RMQ_index`` ops be answered by one dispatch.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import HierarchyPlan
from repro.kernels.rmq_scan.ref import rmq_branchfree_batch


def rmq_fused_batch_ref(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos: Optional[jax.Array],
    ls: jax.Array,
    rs: jax.Array,
    track_pos: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """(values, leftmost-tie positions) for the whole batch, one pass."""
    ls = jnp.asarray(ls, jnp.int32)
    rs = jnp.asarray(rs, jnp.int32)
    return rmq_branchfree_batch(
        plan, base, upper, upper_pos, ls, rs, track_pos=track_pos
    )
