"""Pallas TPU kernel: a whole mixed query batch in ONE launch.

PR 4 collapsed *construction* into a single ``pallas_call``
(``kernels/hierarchy_fused``); this kernel completes the symmetry on the
query side — the paper's "only the relevant portions of the hierarchy are
then processed in an optimized massively-parallel scan operation" as one
launch for the entire batch, with no host-side span-class split:

* **in-kernel span decomposition.**  Each query is decomposed inside the
  kernel into a prefix-chunk scan + per-level boundary lookups + suffix-
  chunk scan — the branch-free walk of ``kernels/rmq_scan``, whose masks
  go empty exactly where the paper's early break fires.  Short spans
  (<= two aligned level-0 chunks) are answered entirely by the level-0
  windows — the upper-level masks are empty by construction — so the
  engine's short/mid/long classification becomes unnecessary: one kernel
  serves the whole mix.
* **level offsets via scalar prefetch.**  The ``plan.offsets`` table
  (the same table ``hierarchy_fused`` consumes) arrives as a scalar-
  prefetch operand (``pltpu.PrefetchScalarGridSpec``): each level's slot
  in the contiguous ``upper`` buffer is indexed *dynamically* while every
  slice size stays static from the plan — the construction and query
  kernels address the hierarchy through one layout contract.
* **value AND index ops in the same launch.**  The position-tracking
  variant emits two planes — minima and leftmost-tie positions — so a
  batch mixing ``RMQ_value`` and ``RMQ_index`` requests needs one launch;
  the host selects the requested plane per query.
* **query-tile staging + double-buffered boundary DMA.**  As in
  ``rmq_scan``: bounds arrive in SMEM per tile, level-0 boundary chunks
  are DMA'd HBM→VMEM with a two-slot pipeline, the upper buffer is
  VMEM-resident for the whole launch.

Tie-breaking: the ``min(pos where value == min)`` form everywhere, which
is bit-identical to the leftmost-argmin oracle (same argument as the
construction kernels).  The padding contract makes the reserved
``capacity > n`` tail (+inf / ``PAD_POS``) unable to win any query.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import POS_INF_I32 as _POS_INF_I32
from repro.core.plan import HierarchyPlan

DEFAULT_QUERY_BLOCK = 256


def _masked_min_2d(vals, idx, lo, hi, pos=None):
    """(min, leftmost-pos) over ``vals`` where ``lo <= idx < hi``."""
    inf = jnp.array(jnp.inf, dtype=vals.dtype)
    mask = (idx >= lo) & (idx < hi)
    masked = jnp.where(mask, vals, inf)
    m = jnp.min(masked)
    if pos is None:
        return m, jnp.int32(_POS_INF_I32)
    cand = jnp.where(mask & (masked == m), pos, _POS_INF_I32)
    return m, jnp.min(cand)


def _merge(m, p, m2, p2):
    take2 = (m2 < m) | ((m2 == m) & (p2 < p))
    return jnp.where(take2, m2, m), jnp.where(take2, p2, p)


def _rmq_fused_kernel(
    # scalar prefetch
    offs_ref,       # SMEM (L-1,) i32: plan.offsets (entry units)
    # inputs
    l_ref,          # SMEM (qb,) i32
    r_ref,          # SMEM (qb,) i32
    base_hbm,       # ANY  (capacity,) level 0, stays in HBM
    upper_ref,      # VMEM (rows, c): all upper levels, one chunk per row
    upper_pos_ref,  # VMEM (rows, c) i32 or None (closure decides)
    # outputs
    out_ref,        # SMEM (qb,) values
    out_pos_ref,    # SMEM (qb,) i32 or None
    # scratch
    win_ref,        # VMEM (2, 2, c) double-buffered boundary windows
    sems,           # DMA semaphores (2, 2)
    *,
    plan: HierarchyPlan,
    qb: int,
    track_pos: bool,
):
    c = plan.c
    n = plan.capacity  # stored base length (+inf-padded past the live tail)
    num_levels = plan.num_levels

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)

    def window_starts(i):
        """Aligned level-0 window anchors for query i."""
        l = l_ref[i]
        r = r_ref[i] + 1
        a_start = jnp.clip((l // c) * c, 0, max(n - c, 0))
        b_start = jnp.clip((r // c) * c, 0, max(n - c, 0))
        return a_start, b_start

    def issue(i, slot):
        a_start, b_start = window_starts(i)
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(a_start, c)], win_ref.at[slot, 0],
            sems.at[slot, 0],
        ).start()
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(b_start, c)], win_ref.at[slot, 1],
            sems.at[slot, 1],
        ).start()

    def wait(i, slot):
        a_start, b_start = window_starts(i)
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(a_start, c)], win_ref.at[slot, 0],
            sems.at[slot, 0],
        ).wait()
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(b_start, c)], win_ref.at[slot, 1],
            sems.at[slot, 1],
        ).wait()

    issue(0, 0)

    def body(i, _):
        slot = jax.lax.rem(i, 2)
        wait(i, slot)

        @pl.when(i + 1 < qb)
        def _prefetch():
            issue(i + 1, 1 - slot)

        l = l_ref[i]
        r = r_ref[i] + 1  # exclusive
        a_start, b_start = window_starts(i)

        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c

        # ---- level 0: the prefix / suffix chunk scans -------------------
        # A short span's two windows cover [l, r) outright; the ascended
        # range below is then empty and every upper mask stays empty —
        # the kernel-internal equivalent of the planner's SHORT route.
        idx_a = a_start + lane
        idx_b = b_start + lane
        pos_a = idx_a if track_pos else None
        pos_b = idx_b if track_pos else None
        m, p = _masked_min_2d(
            win_ref[slot, 0].reshape(1, c), idx_a, l,
            jnp.minimum(next_l, r), pos_a,
        )
        m2, p2 = _masked_min_2d(
            win_ref[slot, 1].reshape(1, c), idx_b,
            jnp.maximum(prev_r, l), r, pos_b,
        )
        m, p = _merge(m, p, m2, p2)

        l_k = (l + c - 1) // c   # ceil
        r_k = r // c             # floor

        # ---- upper levels: dynamic offsets from the prefetched table ----
        for level in range(1, num_levels):
            # Offsets are multiples of c (padded_lens are), so entry
            # offset / c is that level's first sublane row.
            off_rows = offs_ref[level - 1] // c
            padded_rows = plan.padded_lens[level - 1] // c
            is_last = level == num_levels - 1
            if is_last:
                # masked scan of the whole (small, VMEM-resident) top
                rows = padded_rows
                vals = upper_ref[pl.ds(off_rows, rows), :]
                idx = (
                    jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) * c
                    + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
                )
                pos = (
                    upper_pos_ref[pl.ds(off_rows, rows), :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(vals, idx, l_k, r_k, pos)
                m, p = _merge(m, p, m2, p2)
            else:
                a_row = jnp.clip(l_k // c, 0, padded_rows - 1)
                b_row = jnp.clip(r_k // c, 0, padded_rows - 1)
                nl = ((l_k + c - 1) // c) * c
                pr = (r_k // c) * c
                va = upper_ref[pl.ds(off_rows + a_row, 1), :]
                vb = upper_ref[pl.ds(off_rows + b_row, 1), :]
                ia = a_row * c + lane
                ib = b_row * c + lane
                pa = (
                    upper_pos_ref[pl.ds(off_rows + a_row, 1), :]
                    if track_pos
                    else None
                )
                pb = (
                    upper_pos_ref[pl.ds(off_rows + b_row, 1), :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(va, ia, l_k, jnp.minimum(nl, r_k), pa)
                m, p = _merge(m, p, m2, p2)
                m2, p2 = _masked_min_2d(vb, ib, jnp.maximum(pr, l_k), r_k, pb)
                m, p = _merge(m, p, m2, p2)
                l_k = (l_k + c - 1) // c
                r_k = r_k // c

        out_ref[i] = m
        if track_pos:
            out_pos_ref[i] = p
        return 0

    jax.lax.fori_loop(0, qb, body, 0)


def rmq_fused_pallas(
    base: jax.Array,
    upper2d: jax.Array,
    upper_pos2d: Optional[jax.Array],
    offsets: jax.Array,
    ls: jax.Array,
    rs: jax.Array,
    plan: HierarchyPlan,
    qb: int = DEFAULT_QUERY_BLOCK,
    track_pos: bool = False,
    interpret: bool = False,
):
    """Launch the fused query kernel.  ``ls.shape[0]`` must divide by qb.

    ``upper2d`` is the contiguous upper buffer reshaped ``(rows, c)``;
    ``offsets`` is the int32 ``plan.offsets`` table (entry units),
    consumed via scalar prefetch.  Returns ``(values, positions)`` —
    both planes from the one launch when ``track_pos``, positions
    ``INT32_MAX`` otherwise.
    """
    m = ls.shape[0]
    assert m % qb == 0, (m, qb)
    rows = upper2d.shape[0]
    c = plan.c

    kernel = functools.partial(
        _rmq_fused_kernel, plan=plan, qb=qb, track_pos=track_pos
    )

    in_specs = [
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pl.ANY),              # base stays in HBM
        pl.BlockSpec((rows, c), lambda i, offs: (0, 0)),  # upper: resident
    ]
    out_specs = [
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
    ]
    out_shape = [jax.ShapeDtypeStruct((m,), base.dtype)]

    if track_pos:
        in_specs.append(pl.BlockSpec((rows, c), lambda i, offs: (0, 0)))
        out_specs.append(
            pl.BlockSpec((qb,), lambda i, offs: (i,),
                         memory_space=pltpu.SMEM)
        )
        out_shape.append(jax.ShapeDtypeStruct((m,), jnp.int32))
        args = (ls, rs, base, upper2d, upper_pos2d)

        def kern(offs_ref, l_ref, r_ref, base_h, up_ref, upos_ref, o_ref,
                 opos_ref, win, sems):
            kernel(offs_ref, l_ref, r_ref, base_h, up_ref, upos_ref,
                   o_ref, opos_ref, win, sems)
    else:
        args = (ls, rs, base, upper2d)

        def kern(offs_ref, l_ref, r_ref, base_h, up_ref, o_ref, win, sems):
            kernel(offs_ref, l_ref, r_ref, base_h, up_ref, None, o_ref,
                   None, win, sems)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // qb,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, 2, c), base.dtype),   # [slot][side][c] dbl-buf
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(offsets.astype(jnp.int32), *args)
    if track_pos:
        return out[0], out[1]
    return out[0], None
