"""Jitted wrappers for the fused single-launch query path.

One call = one device dispatch for the *entire* mixed batch, every span
class, both output planes:

* **TPU** — the ``kernel.py`` ``pallas_call`` (offsets via scalar
  prefetch, VMEM-resident upper buffer, double-buffered level-0 DMA).
* **elsewhere** — a single end-to-end-jitted jnp program realizing the
  same contract: the branch-free walk for levels ``0..L-2`` plus a
  sparse-table top *built inside the program* from the hierarchy's own
  top level.  Building the (<= c·t entry) table per batch is the CPU
  analogue of the kernel keeping the top VMEM-resident: its cost
  amortizes over the batch and every top lookup becomes O(1) — which is
  what keeps fused long-span throughput at (or past) the routed engine's
  hybrid path without any host-side class split.  Results are
  bit-identical to the walk (the hybrid algebra's parity is part of the
  engine contract).

Launch accounting: both lowerings call
:func:`repro.kernels.profiling.record_launch` (``"rmq_fused"``) from
inside their traced bodies — one recorded launch per batch is the
assertable contract, regardless of lowering (on TPU it is literally one
``pallas_call``).  Degenerate-but-valid geometries (single-level plans,
``capacity < c``) run the jnp program on every backend: they have no
multi-level hierarchy for the kernel to exploit, but the one-dispatch
contract still holds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.baselines import SparseTable
from repro.core.hierarchy import Hierarchy
from repro.core.hybrid import _hybrid_batch
from repro.core.plan import HierarchyPlan
from repro.core.query import _rmq_batch_impl
from repro.kernels import profiling
from repro.kernels.rmq_fused import kernel as K

__all__ = [
    "rmq_fused_batch",
    "rmq_fused_value_batch",
    "rmq_fused_index_batch",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_applicable(plan: HierarchyPlan) -> bool:
    return plan.num_levels >= 2 and plan.capacity >= plan.c


@functools.partial(jax.jit, static_argnames=("plan", "track_pos"))
def _fused_jnp(base, upper, upper_pos, ls, rs, plan, track_pos):
    """The one-dispatch jnp lowering (walk + in-program sparse top)."""
    profiling.record_launch(
        "rmq_fused",
        lowering="jnp",
        queries=int(ls.shape[0]),
        levels=plan.num_levels,
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(
            base, upper, upper_pos, ls, rs),
    )
    if upper.dtype != base.dtype:
        # bf16 summaries: the hybrid algebra's sparse top would compare
        # quantized values, so the one dispatch is the exact-recovery
        # walk instead — same single-launch contract, exact results.
        return _rmq_batch_impl(plan, base, upper, upper_pos, ls, rs,
                               track_pos)
    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    if plan.num_levels == 1:
        top = base  # the plan is a pure scan; the top level IS level 0
        top_pos = (
            jnp.arange(base.shape[0], dtype=jnp.int32)
            if track_pos
            else None
        )
    else:
        off, _ = plan.level_slice(plan.num_levels - 1)
        top = jax.lax.slice(upper, (off,), (off + plan.top_len,))
        top_pos = (
            jax.lax.slice(upper_pos, (off,), (off + plan.top_len,))
            if track_pos
            else None
        )
    tbl = SparseTable.build(top, positions=top_pos)
    return _hybrid_batch(
        plan, base, upper, upper_pos if track_pos else None,
        tbl.table, tbl.pos, ls, rs, track_pos,
    )


@functools.partial(
    jax.jit, static_argnames=("plan", "qb", "track_pos", "interpret")
)
def _run_kernel(base, upper, upper_pos, ls, rs, plan, qb, track_pos,
                interpret):
    m = ls.shape[0]
    m_pad = -(-m // qb) * qb
    profiling.record_launch(
        "rmq_fused",
        lowering="pallas",
        queries=int(m),
        grid=int(m_pad // qb),
        levels=plan.num_levels,
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(
            base, upper, upper_pos, ls, rs),
    )
    if m_pad != m:
        ls = jnp.pad(ls, (0, m_pad - m))
        rs = jnp.pad(rs, (0, m_pad - m))
    # Packed planes unpack to absolute positions inside this same
    # program; the kernel always consumes the classic (rows, c) layout.
    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    upper2d = upper.reshape(-1, plan.c)
    upos2d = upper_pos.reshape(-1, plan.c) if track_pos else None
    offs = jnp.asarray(plan.offsets, jnp.int32)
    vals, pos = K.rmq_fused_pallas(
        base,
        upper2d,
        upos2d,
        offs,
        ls.astype(jnp.int32),
        rs.astype(jnp.int32),
        plan,
        qb=qb,
        track_pos=track_pos,
        interpret=interpret,
    )
    if track_pos:
        return vals[:m], pos[:m]
    return vals[:m], None


def rmq_fused_batch(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    track_pos: bool = False,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
):
    """``(values, positions)`` for the whole batch, one device dispatch.

    ``positions`` is ``None`` unless ``track_pos`` — with it, both
    planes come out of the same launch, so a batch mixing value and
    index ops pays one dispatch total.  ``interpret=None`` picks the
    production lowering (kernel on TPU, the jnp program elsewhere);
    ``interpret=True`` forces the kernel in interpreter mode (the
    correctness tool the test suite uses off-TPU).
    """
    ls = jnp.asarray(ls, jnp.int32)
    rs = jnp.asarray(rs, jnp.int32)
    if track_pos and not h.with_positions:
        raise ValueError(
            "hierarchy was built without positions; "
            "use build_hierarchy(..., with_positions=True)"
        )
    plan = h.plan
    quantized = h.upper.dtype != h.base.dtype
    use_kernel = _kernel_applicable(plan) and not quantized and (
        _on_tpu() if interpret is None else bool(interpret) or _on_tpu()
    )
    if use_kernel:
        itp = False if interpret is None else bool(interpret)
        return _run_kernel(
            h.base, h.upper, h.upper_pos if track_pos else None,
            ls, rs, plan, qb, track_pos, itp,
        )
    # bf16 summaries need the position plane even for value-only batches
    # (exact recovery reads level 0 through stored positions).
    pos_plane = h.upper_pos if (track_pos or quantized) else None
    return _fused_jnp(
        h.base, h.upper, pos_plane,
        ls, rs, plan, track_pos,
    )


def rmq_fused_value_batch(
    h: Hierarchy, ls, rs, qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched ``RMQ_value`` through the fused single-launch path."""
    vals, _ = rmq_fused_batch(
        h, ls, rs, track_pos=False, qb=qb, interpret=interpret
    )
    return vals


def rmq_fused_index_batch(
    h: Hierarchy, ls, rs, qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched ``RMQ_index`` (leftmost minimum) through the fused path."""
    _, pos = rmq_fused_batch(
        h, ls, rs, track_pos=True, qb=qb, interpret=interpret
    )
    return pos
