"""Dispatching wrapper: Pallas flash attention on TPU, reference elsewhere.

The dry-run/roofline path always uses the reference einsum implementation
so XLA's cost model counts attention FLOPs exactly; the Pallas kernel is
selected on real TPU backends (and exercised in interpret mode by tests).
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention.ref import attention_ref, blocked_attention

BLOCKED_MIN_SEQ = 2048  # below this the dense reference is cheaper


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(
    q, k, v,
    scale: float | None = None,
    window=None,
    causal: bool = True,
    impl: str = "auto",
    interpret: bool = False,
):
    """impl: 'auto' | 'ref' | 'blocked' | 'pallas'.

    'auto': Pallas flash kernel on TPU; blocked (flash-style pure JAX) on
    other backends for long sequences; dense reference otherwise.
    ``window`` may be traced only on the ref/blocked paths.
    """
    s = q.shape[2]
    if impl == "auto":
        if _on_tpu():
            impl = "pallas"
        elif s >= BLOCKED_MIN_SEQ and s % 512 == 0:
            impl = "blocked"
        else:
            impl = "ref"
    static_window = isinstance(window, (int, type(None)))
    if impl == "pallas" and causal and static_window \
            and q.shape[2] == k.shape[2] and q.shape[3] == v.shape[3] \
            and q.shape[2] % K.DEFAULT_BLOCK_Q == 0:
        return K.flash_attention(
            q, k, v, scale=scale, window=window, interpret=interpret
        )
    if impl in ("blocked", "pallas") and s % 512 == 0 and causal:
        return blocked_attention(
            q, k, v, scale=scale, window=window, causal=causal
        )
    return attention_ref(q, k, v, scale=scale, window=window, causal=causal)
