"""Pallas TPU kernel: blocked causal flash attention with GQA + SWA.

Framework hot-spot kernel (not a paper contribution — the paper's kernels
are rmq_scan / hierarchy_build).  Used by the transformer stack on TPU;
the pure-jnp reference (ref.py) is the oracle and the CPU/dry-run path.

Design:
* grid ``(B, Hq, nQ, nK)`` with the K dimension innermost (sequential on
  TPU), online-softmax accumulators in VMEM scratch.
* causal + sliding-window block skipping: out-of-range K blocks are
  skipped with ``pl.when`` (scalar condition on program ids — true block
  skip, not masking) and their DMAs are redirected to the diagonal block
  by clamping in the kv index_map, so skipped blocks cost neither compute
  nor bandwidth.
* GQA: the kv index_map maps query head ``h`` to kv head ``h // group``;
  KV is never materialized per-query-head.
* accumulation in f32 regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    bq: int, bk: int, head_dim: int,
    scale: float, window: int | None, num_k_blocks: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    # Block-level causal/window bounds for query block i:
    #   visit j iff j*bk <= (i+1)*bq - 1  (causal)
    #   and  (j+1)*bk - 1 >= i*bq - window + 1  (window lower edge)
    causal_ok = j * bk <= (i + 1) * bq - 1
    if window is not None:
        window_ok = (j + 1) * bk - 1 >= i * bq - window + 1
    else:
        window_ok = True

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(jnp.logical_and(causal_ok, window_ok))
    def _compute():
        q = q_ref[...].reshape(bq, head_dim).astype(jnp.float32) * scale
        k = k_ref[...].reshape(bk, head_dim).astype(jnp.float32)
        v = v_ref[...].reshape(bk, head_dim).astype(jnp.float32)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)

        row = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        col = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = col <= row
        if window is not None:
            mask = mask & (col > row - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_cur

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        out = acc_scr[...] / safe_l[:, None]
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    scale: float | None = None,
    window: int | None = None,
    bq: int = DEFAULT_BLOCK_Q,
    bk: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, GQA-aware."""
    batch, hq, s, d = q.shape
    _, hkv, sk, dk = k.shape
    assert s % bq == 0 and sk % bk == 0, (s, sk, bq, bk)
    assert d == dk and hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    n_q = s // bq
    n_k = sk // bk

    def kv_index(b, h, i, j):
        # clamp skipped blocks' DMA to the diagonal region
        jc = jnp.minimum(j, jnp.minimum((((i + 1) * bq - 1) // bk), n_k - 1))
        if window is not None:
            lo = jnp.maximum((i * bq - window + 1) // bk, 0)
            jc = jnp.maximum(jc, lo)
        return (b, h // group, jc, 0)

    kernel = functools.partial(
        _flash_kernel,
        bq=bq, bk=bk, head_dim=d, scale=scale, window=window,
        num_k_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(batch, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
