"""Pure-jnp oracle for flash attention (also the CPU / dry-run path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,   # (B, Hkv, Sk, Dv)
    scale: float | None = None,
    window: int | None = None,
    causal: bool = True,
) -> jax.Array:
    """Reference causal/sliding-window attention with GQA."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    row = jnp.arange(s)[:, None] + (sk - s)  # align ends (decode: s=1)
    col = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), dtype=bool)
    if causal:
        mask = mask & (col <= row)
    if window is not None:
        mask = mask & (col > row - window)
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,   # (B, Hkv, Sk, Dv)
    scale: float | None = None,
    window=None,    # None | int | traced scalar
    causal: bool = True,
    block_q: int = 512,
) -> jax.Array:
    """Flash-style attention in pure JAX: O(block_q · S) live scores.

    The dry-run / CPU production path for long sequences — XLA counts the
    same FLOPs as a fused kernel but the (S, S) score matrix never
    materializes (lax.map over query blocks + jax.checkpoint on the block
    body, so the backward pass recomputes block scores instead of saving
    them).  ``window`` may be a traced scalar (hybrid archs scan per-layer
    windows).
    """
    b, hq, s, d = q.shape
    _, hkv, sk, dv = v.shape
    assert s % block_q == 0, (s, block_q)
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    nq = s // block_q
    qb = q.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    offset = sk - s  # decode-style alignment (s == sk in train/prefill)

    @jax.checkpoint
    def one_block(args):
        i, qi = args                        # qi: (B, H, block_q, D)
        scores = jnp.einsum(
            "bhqd,bhkd->bhqk", qi.astype(jnp.float32),
            k.astype(jnp.float32),
        ) * scale
        row = i * block_q + jnp.arange(block_q)[:, None] + offset
        col = jnp.arange(sk)[None, :]
        mask = jnp.ones((block_q, sk), bool)
        if causal:
            mask = mask & (col <= row)
        if window is not None:
            mask = mask & (col > row - window)
        scores = jnp.where(mask[None, None], scores, _NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum(
            "bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
        ).astype(q.dtype)

    out = jax.lax.map(one_block, (jnp.arange(nq), qb))
    return out.transpose(1, 2, 0, 3, 4).reshape(b, hq, s, dv)
