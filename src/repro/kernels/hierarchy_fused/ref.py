"""Pure-jnp oracle for the fused single-launch build.

The fused kernel's contract is bit-identity with
``repro.core.hierarchy.build_hierarchy`` — which, since the pipeline
refactor, *is* the single-pass preallocated-buffer build (each level
reduced straight into its ``plan.offsets`` slot, fill values doubling as
padding, no concatenate).  Rather than keep a line-for-line copy of that
loop here that could drift, the oracle delegates to it; this module only
adapts the kernel-facing calling convention (a capacity-padded level 0
in, bare upper planes out).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core.hierarchy import build_hierarchy
from repro.core.plan import HierarchyPlan


def fused_build_ref(
    base: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Upper planes from a capacity-padded level 0: ``-> (upper[, pos])``.

    ``base`` is the stored level 0 (``capacity`` long, +inf past the
    live region — re-padding its first ``plan.n`` entries reproduces it
    exactly, so the oracle build sees identical input).
    """
    assert base.shape[0] == plan.capacity, (base.shape, plan)
    h = build_hierarchy(base[: plan.n], plan, with_positions=with_positions)
    return h.upper, h.upper_pos
