"""Pallas TPU kernel: the whole upper hierarchy in ONE launch.

The per-level build kernel (``kernels/hierarchy_build``) issues one
``pallas_call`` per level with host-side pad/slice glue between launches;
the paper's construction story ("a handful of fused parallel reductions")
is a single pass.  This kernel realizes that on TPU:

* the grid streams **level 0** through VMEM tile by tile — each step DMAs
  a ``(tile_out * c,)`` contiguous slice HBM→VMEM, reshapes to
  ``(tile_out, c)`` and VPU-reduces it to ``tile_out`` level-1 summaries,
  exactly the per-level kernel's inner step;
* the contiguous ``upper`` buffer is the kernel's only output and stays
  **VMEM-resident for the entire launch** (whole-array BlockSpec), so
  every level's summaries are written directly at its ``plan.offsets``
  slot — no intermediate per-level arrays, no concatenate;
* the **final grid step** folds the remaining levels bottom-up entirely
  in VMEM, each fold reading the level just written from the output
  buffer itself — no HBM round-trip exists between levels;
* the level-offset table arrives via **scalar prefetch**
  (``pltpu.PrefetchScalarGridSpec``): offsets index the contiguous buffer
  dynamically while every slice *size* stays static from the plan;
* level-0 **positions are synthesized in-kernel** (a masked iota from the
  grid step id) — the per-level path materializes a ``(capacity,)`` iota
  in HBM first, roughly doubling its build-time input traffic for
  position-tracking builds.

Tie-breaking note: position outputs use the ``min(pos where value ==
min)`` form rather than ``pos[argmin]``.  Carried positions increase
strictly across a chunk's non-padding entries (each summarizes an earlier
subtree than its right neighbour; padding holds ``PAD_POS = INT32_MAX``),
so the two forms agree bit-exactly with the leftmost-argmin oracle while
avoiding a dynamic gather — same argument as ``kernels/hierarchy_update``.

Padding contract: the buffer is +inf / ``PAD_POS``-filled on the first
grid step, and only live entries are overwritten — so each level's stored
padding (out to a multiple of ``c``) matches the oracle's by construction.

VMEM budget: the whole ``upper`` buffer (≈ capacity/(c-1) entries per
plane) plus one double-buffered input tile must fit; ops.py enforces this
before launching and points callers past it at the per-level backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import PAD_POS
from repro.core.plan import HierarchyPlan

DEFAULT_TILE_OUT = 512


def _fold_upper_levels(offs_ref, o_ref, po_ref, *, c: int,
                       plan: HierarchyPlan, pos_dtype):
    """Bottom-up folds for levels >= 2, entirely on the VMEM-resident
    output buffer.  Reducing a level's whole *padded* extent yields
    exactly the next level's live length (``padded_lens[k-2] / c ==
    level_lens[k]``), so each fold writes only live entries and the
    initialization padding survives untouched."""
    for k in range(2, plan.num_levels):
        src_len = plan.padded_lens[k - 2]
        out_len = src_len // c  # == plan.level_lens[k]
        sv = o_ref[pl.ds(offs_ref[k - 2], src_len)].reshape(out_len, c)
        mv = jnp.min(sv, axis=1)
        o_ref[pl.ds(offs_ref[k - 1], out_len)] = mv
        if po_ref is not None:
            sp = po_ref[pl.ds(offs_ref[k - 2], src_len)].reshape(out_len, c)
            mp = jnp.min(
                jnp.where(sv == mv[:, None], sp, jnp.array(PAD_POS, pos_dtype)),
                axis=1,
            )
            po_ref[pl.ds(offs_ref[k - 1], out_len)] = mp


def _fused_kernel(offs_ref, x_ref, o_ref, *, c: int, tile_out: int,
                  plan: HierarchyPlan):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, jnp.inf, o_ref.dtype)

    v = x_ref[...].reshape(tile_out, c)
    o_ref[pl.ds(offs_ref[0] + i * tile_out, tile_out)] = jnp.min(v, axis=1)

    @pl.when(i == pl.num_programs(0) - 1)
    def _fold():
        _fold_upper_levels(offs_ref, o_ref, None, c=c, plan=plan,
                           pos_dtype=None)


def _fused_kernel_with_positions(offs_ref, x_ref, o_ref, po_ref, *, c: int,
                                 tile_out: int, cap: int,
                                 plan: HierarchyPlan, pos_dtype):
    i = pl.program_id(0)
    pad_pos = jnp.array(PAD_POS, pos_dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, jnp.inf, o_ref.dtype)
        po_ref[...] = jnp.full(po_ref.shape, PAD_POS, pos_dtype)

    v = x_ref[...].reshape(tile_out, c)
    m = jnp.min(v, axis=1)
    # Level-0 positions are the absolute indices, synthesized from the
    # grid step (+inf padding past capacity gets the PAD_POS sentinel,
    # matching the oracle's padded iota).
    row = jax.lax.broadcasted_iota(jnp.int32, (tile_out, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (tile_out, c), 1)
    gidx = i * (tile_out * c) + row * c + col
    p = jnp.where(gidx < cap, gidx, PAD_POS).astype(pos_dtype)
    pm = jnp.min(jnp.where(v == m[:, None], p, pad_pos), axis=1)
    start = offs_ref[0] + i * tile_out
    o_ref[pl.ds(start, tile_out)] = m
    po_ref[pl.ds(start, tile_out)] = pm

    @pl.when(i == pl.num_programs(0) - 1)
    def _fold():
        _fold_upper_levels(offs_ref, o_ref, po_ref, c=c, plan=plan,
                           pos_dtype=pos_dtype)


def fused_build(
    values: jax.Array,
    offsets: jax.Array,
    plan: HierarchyPlan,
    tile_out: int = DEFAULT_TILE_OUT,
    interpret: bool = False,
) -> jax.Array:
    """ALL upper levels from padded level 0, one launch: ``-> (upper_size,)``.

    ``values`` must be padded to ``plan.padded_lens[0] * plan.c`` with
    +inf and ``tile_out`` must divide ``plan.padded_lens[0]`` (ops.py
    arranges both).  ``offsets`` is the int32 ``plan.offsets`` table,
    consumed via scalar prefetch.
    """
    c = plan.c
    total = values.shape[0]
    assert total == plan.padded_lens[0] * c, (total, plan)
    assert plan.padded_lens[0] % tile_out == 0, (plan.padded_lens[0], tile_out)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(plan.padded_lens[0] // tile_out,),
        in_specs=[pl.BlockSpec((tile_out * c,), lambda i, offs: (i,))],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, c=c, tile_out=tile_out, plan=plan),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((plan.upper_size,), values.dtype),
        interpret=interpret,
    )(offsets.astype(jnp.int32), values)


def fused_build_with_positions(
    values: jax.Array,
    offsets: jax.Array,
    plan: HierarchyPlan,
    pos_dtype,
    tile_out: int = DEFAULT_TILE_OUT,
    interpret: bool = False,
):
    """Fused build carrying leftmost-minimum original-array positions."""
    c = plan.c
    total = values.shape[0]
    assert total == plan.padded_lens[0] * c, (total, plan)
    assert plan.padded_lens[0] % tile_out == 0, (plan.padded_lens[0], tile_out)
    pos_dtype = jnp.dtype(pos_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(plan.padded_lens[0] // tile_out,),
        in_specs=[pl.BlockSpec((tile_out * c,), lambda i, offs: (i,))],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _fused_kernel_with_positions, c=c, tile_out=tile_out,
            cap=plan.capacity, plan=plan, pos_dtype=pos_dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((plan.upper_size,), values.dtype),
            jax.ShapeDtypeStruct((plan.upper_size,), pos_dtype),
        ],
        interpret=interpret,
    )(offsets.astype(jnp.int32), values)
