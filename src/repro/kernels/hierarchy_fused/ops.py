"""Jitted wrapper: build a full Hierarchy in ONE fused Pallas launch.

Produces a ``Hierarchy`` pytree bit-identical to
``repro.core.hierarchy.build_hierarchy`` (the oracle) — values *and*
leftmost-tie positions, padding included — with exactly one kernel launch
per build (``repro.kernels.profiling`` makes that assertable).  The whole
entry point is end-to-end jitted: padding, the launch, and the pytree
assembly compile into one XLA program, so nothing bounces through the
host between levels.

Falls back to interpret mode off-TPU, like every kernel package here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hierarchy import Hierarchy, _pad_to, pos_dtype_for
from repro.core.plan import HierarchyPlan
from repro.kernels import profiling
from repro.kernels.hierarchy_fused import kernel as K

__all__ = ["build_hierarchy_fused", "FUSED_VMEM_BUDGET_BYTES"]

# The upper buffer lives wholly in VMEM for the launch (~16 MiB/core on
# current TPUs); leave headroom for the double-buffered input tile.  With
# c=128 this admits capacities up to ~250M elements (half that with
# positions) — past it, use the per-level 'pallas' backend.
FUSED_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile_out(padded_level1: int) -> int:
    """Largest power-of-two tile (<= default) dividing level 1's extent."""
    tile = K.DEFAULT_TILE_OUT
    while tile > 1 and padded_level1 % tile != 0:
        tile //= 2
    return tile


@functools.partial(
    jax.jit, static_argnames=("plan", "with_positions", "tile_out",
                              "interpret")
)
def _fused_jit(x, plan, with_positions, tile_out, interpret):
    c = plan.c
    inf = jnp.array(jnp.inf, x.dtype)
    base = _pad_to(x, plan.capacity, inf)
    # Tile-align level 0 for the kernel's block DMA; the over-pad is
    # < c * tile_out entries and the all-inf chunks it adds reduce to the
    # same +inf / PAD_POS padding the oracle stores.
    xin = _pad_to(base, plan.padded_lens[0] * c, inf)
    offs = jnp.asarray(plan.offsets, jnp.int32)
    profiling.record_launch(
        "hierarchy_fused",
        lowering="pallas",
        levels=plan.num_levels,
        grid=int(plan.padded_lens[0] // tile_out),
        with_positions=bool(with_positions),
        operand_bytes=profiling.operand_bytes(xin, offs),
    )
    if with_positions:
        upper, upper_pos = K.fused_build_with_positions(
            xin, offs, plan, pos_dtype_for(plan.capacity),
            tile_out=tile_out, interpret=interpret,
        )
    else:
        upper = K.fused_build(
            xin, offs, plan, tile_out=tile_out, interpret=interpret
        )
        upper_pos = None
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)


@functools.partial(jax.jit, static_argnames=("plan", "with_positions"))
def _single_level_jit(x, plan, with_positions):
    # n <= c*t: the plan is a pure scan, no upper levels and no launch.
    base = _pad_to(x, plan.capacity, jnp.array(jnp.inf, x.dtype))
    pos_dtype = pos_dtype_for(plan.capacity) if with_positions else None
    upper = jnp.full((0,), jnp.inf, x.dtype)
    upper_pos = (
        jnp.full((0,), 0, pos_dtype) if with_positions else None
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)


def build_hierarchy_fused(
    x: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
    interpret: bool | None = None,
) -> Hierarchy:
    """Single-launch fused build (paper §4.1, all levels in one pass)."""
    if interpret is None:
        interpret = not _on_tpu()
    if plan.num_levels == 1:
        return _single_level_jit(x, plan, with_positions)
    if with_positions and plan.padded_lens[0] * plan.c >= 2**31:
        # The kernel synthesizes absolute level-0 positions in int32.
        raise NotImplementedError(
            "the fused build supports position-tracking capacities < 2**31;"
            " use backend='jax' for larger arrays"
        )
    x = jnp.asarray(x)
    tile_out = _pick_tile_out(plan.padded_lens[0])
    if not interpret:
        itemsize = jnp.dtype(x.dtype).itemsize
        vmem = plan.upper_size * itemsize
        if with_positions:
            vmem += plan.upper_size * jnp.dtype(
                pos_dtype_for(plan.capacity)
            ).itemsize
        vmem += 2 * tile_out * plan.c * itemsize  # double-buffered input
        if vmem > FUSED_VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused build needs ~{vmem} bytes of VMEM for this plan "
                f"(budget {FUSED_VMEM_BUDGET_BYTES}); use the per-level "
                "backend='pallas' for this geometry"
            )
    return _fused_jit(x, plan, with_positions, tile_out, interpret)
