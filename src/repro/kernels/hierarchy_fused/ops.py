"""Jitted wrapper: build a full Hierarchy in ONE fused Pallas launch.

Produces a ``Hierarchy`` pytree bit-identical to
``repro.core.hierarchy.build_hierarchy`` (the oracle) — values *and*
leftmost-tie positions, padding included — with exactly one kernel launch
per build (``repro.kernels.profiling`` makes that assertable).  The whole
entry point is end-to-end jitted: padding, the launch, and the pytree
assembly compile into one XLA program, so nothing bounces through the
host between levels.

Falls back to interpret mode off-TPU, like every kernel package here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.constants import PAD_POS
from repro.core.hierarchy import (
    Hierarchy,
    _check_compact_build,
    _pad_to,
    finalize_compact,
    pos_dtype_for,
)
from repro.core.plan import HierarchyPlan, make_plan
from repro.kernels import profiling
from repro.kernels.hierarchy_fused import kernel as K

__all__ = [
    "build_hierarchy_fused",
    "build_hierarchy_streamed",
    "FUSED_VMEM_BUDGET_BYTES",
]

# The upper buffer lives wholly in VMEM for the launch (~16 MiB/core on
# current TPUs); leave headroom for the double-buffered input tile.  With
# c=128 this admits capacities up to ~250M elements (half that with
# positions) — past it, use the per-level 'pallas' backend.
FUSED_VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile_out(padded_level1: int) -> int:
    """Largest power-of-two tile (<= default) dividing level 1's extent."""
    tile = K.DEFAULT_TILE_OUT
    while tile > 1 and padded_level1 % tile != 0:
        tile //= 2
    return tile


@functools.partial(
    jax.jit, static_argnames=("plan", "with_positions", "tile_out",
                              "interpret")
)
def _fused_jit(x, plan, with_positions, tile_out, interpret):
    c = plan.c
    inf = jnp.array(jnp.inf, x.dtype)
    base = _pad_to(x, plan.capacity, inf)
    # Tile-align level 0 for the kernel's block DMA; the over-pad is
    # < c * tile_out entries and the all-inf chunks it adds reduce to the
    # same +inf / PAD_POS padding the oracle stores.
    xin = _pad_to(base, plan.padded_lens[0] * c, inf)
    offs = jnp.asarray(plan.offsets, jnp.int32)
    profiling.record_launch(
        "hierarchy_fused",
        lowering="pallas",
        levels=plan.num_levels,
        grid=int(plan.padded_lens[0] // tile_out),
        with_positions=bool(with_positions),
        operand_bytes=profiling.operand_bytes(xin, offs),
    )
    if with_positions:
        upper, upper_pos = K.fused_build_with_positions(
            xin, offs, plan, pos_dtype_for(plan.capacity),
            tile_out=tile_out, interpret=interpret,
        )
    else:
        upper = K.fused_build(
            xin, offs, plan, tile_out=tile_out, interpret=interpret
        )
        upper_pos = None
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)


@functools.partial(jax.jit, static_argnames=("plan", "with_positions"))
def _single_level_jit(x, plan, with_positions):
    # n <= c*t: the plan is a pure scan, no upper levels and no launch.
    base = _pad_to(x, plan.capacity, jnp.array(jnp.inf, x.dtype))
    pos_dtype = pos_dtype_for(plan.capacity) if with_positions else None
    upper = jnp.full((0,), jnp.inf, x.dtype)
    upper_pos = (
        jnp.full((0,), 0, pos_dtype) if with_positions else None
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)


def build_hierarchy_fused(
    x: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
    interpret: bool | None = None,
) -> Hierarchy:
    """Single-launch fused build (paper §4.1, all levels in one pass)."""
    from repro.core.protocol import check_capacity_limit

    if interpret is None:
        interpret = not _on_tpu()
    _check_compact_build(plan, with_positions, jnp.asarray(x).dtype)
    if plan.num_levels == 1:
        return finalize_compact(_single_level_jit(x, plan, with_positions))
    if with_positions:
        # The kernel synthesizes absolute level-0 positions in int32 over
        # the tile-aligned input extent; x64 does not help here — route
        # larger arrays through backend='jax' or the streamed build.
        check_capacity_limit(plan.padded_lens[0] * plan.c)
    x = jnp.asarray(x)
    tile_out = _pick_tile_out(plan.padded_lens[0])
    if not interpret:
        itemsize = jnp.dtype(x.dtype).itemsize
        vmem = plan.upper_size * itemsize
        if with_positions:
            vmem += plan.upper_size * jnp.dtype(
                pos_dtype_for(plan.capacity)
            ).itemsize
        vmem += 2 * tile_out * plan.c * itemsize  # double-buffered input
        if vmem > FUSED_VMEM_BUDGET_BYTES:
            raise ValueError(
                f"fused build needs ~{vmem} bytes of VMEM for this plan "
                f"(budget {FUSED_VMEM_BUDGET_BYTES}); use the per-level "
                "backend='pallas' for this geometry"
            )
    return finalize_compact(
        _fused_jit(x, plan, with_positions, tile_out, interpret)
    )


# --------------------------------------------------------------------------
# Out-of-core construction: stream fixed-size segments through the fused
# kernel, then finish the (tiny) levels >= 2 from the assembled level 1.
# --------------------------------------------------------------------------


def _segment_plan(segment_size: int, c: int) -> HierarchyPlan:
    """A two-level plan covering exactly one ``segment_size`` slab.

    ``t = ceil(S / c^2)`` makes level 1 (``S/c`` entries) the top level:
    each fused launch reduces its slab to chunk minima and stops, so the
    slab's VMEM footprint is ``S/c`` entries — independent of the full
    array's size.
    """
    t = max(1, -(-segment_size // (c * c)))
    seg = make_plan(segment_size, c=c, t=t)
    if seg.num_levels != 2 or seg.level_lens[1] * c != segment_size:
        raise AssertionError(
            f"segment plan for S={segment_size}, c={c} is not a clean "
            f"two-level reduction (levels={seg.num_levels})"
        )
    return seg


def _read_segment(source, start: int, stop: int):
    """One slab of input values: callable ``source(start, stop)`` or any
    sliceable array-like (memmap, numpy, jax array)."""
    if callable(source):
        return jnp.asarray(source(start, stop))
    return jnp.asarray(source[start:stop])


@functools.partial(jax.jit, static_argnames=("plan", "with_positions"))
def _finish_from_level1(base, l1_vals, l1_pos, plan, with_positions):
    """Replay the oracle's reduction loop from level 2 upward.

    ``l1_vals``/``l1_pos`` are level 1's live entries (``level_lens[1]``
    of them, positions absolute) exactly as the oracle would have stored
    them; everything above is bit-for-bit the
    :func:`repro.core.hierarchy.build_hierarchy` loop, so the streamed
    build inherits the oracle's full parity contract (padding, leftmost
    ties, compact finalization).
    """
    c = plan.c
    inf = jnp.array(jnp.inf, base.dtype)
    upper = jnp.full((plan.upper_size,), jnp.inf, dtype=base.dtype)
    upper = jax.lax.dynamic_update_slice(upper, l1_vals, (plan.offsets[0],))
    if with_positions:
        pos_dtype = l1_pos.dtype
        pad = jnp.array(PAD_POS, pos_dtype)
        upper_pos = jnp.full((plan.upper_size,), PAD_POS, dtype=pos_dtype)
        upper_pos = jax.lax.dynamic_update_slice(
            upper_pos, l1_pos, (plan.offsets[0],)
        )
    else:
        upper_pos = None
    cur_v, cur_p = l1_vals, l1_pos
    for k in range(2, plan.num_levels):
        want = plan.level_lens[k] * c
        v = _pad_to(cur_v, want, inf).reshape(-1, c)
        idx = jnp.argmin(v, axis=1)
        nxt_v = jnp.take_along_axis(v, idx[:, None], axis=1)[:, 0]
        off = plan.offsets[k - 1]
        upper = jax.lax.dynamic_update_slice(upper, nxt_v, (off,))
        if with_positions:
            p = _pad_to(cur_p, want, pad).reshape(-1, c)
            nxt_p = jnp.take_along_axis(p, idx[:, None], axis=1)[:, 0]
            upper_pos = jax.lax.dynamic_update_slice(
                upper_pos, nxt_p, (off,)
            )
            cur_p = nxt_p
        cur_v = nxt_v
    return finalize_compact(
        Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)
    )


def build_hierarchy_streamed(
    source,
    plan: HierarchyPlan,
    with_positions: bool = False,
    segment_size: int | None = None,
    interpret: bool | None = None,
) -> Hierarchy:
    """Out-of-core fused construction: one slab at a time.

    The monolithic fused build keeps the whole upper buffer VMEM-resident,
    which caps the capacities it admits.  This path streams fixed-size
    segments (``segment_size`` elements, a multiple of ``c``) through the
    fused kernel — each launch's working set is one slab plus its ``S/c``
    chunk minima — assembles the global level 1, and finishes the
    geometrically smaller levels >= 2 with the pure-JAX oracle loop.

    ``source`` is either a sliceable array-like (numpy memmap, array) or
    a callable ``source(start, stop) -> values`` producing slabs on
    demand, so the input never has to exist as one device array during
    level-1 construction.  Under x64, position-tracking builds past
    ``2**31`` elements get an int64 coordinate plane; without x64 they
    raise (the strict ``pos_dtype_for`` guard).

    The result is bit-identical to ``build_hierarchy(x, plan, ...)`` —
    values, leftmost-tie positions, padding, and any compact layout
    (``packed_pos`` / bf16 summaries) the plan selects.
    """
    c = plan.c
    cap = plan.capacity
    n = plan.n
    if segment_size is None:
        segment_size = min(c * 4096, -(-cap // c) * c)
        segment_size = max(segment_size, 2 * c)
    if segment_size % c != 0 or segment_size < 2 * c:
        raise ValueError(
            f"segment_size must be a multiple of c={c} and >= {2 * c}, "
            f"got {segment_size}"
        )
    probe = _read_segment(source, 0, min(n, segment_size))
    _check_compact_build(plan, with_positions, probe.dtype)
    if plan.num_levels == 1:
        # Pure-scan plans have no level 1 to assemble; the monolithic
        # path is already out-of-core-trivial.
        full = probe if probe.shape[0] >= n else _read_segment(source, 0, n)
        return build_hierarchy_fused(
            full, plan, with_positions, interpret=interpret,
        )
    # Strict: raises without x64 past 2**31 instead of wrapping silently.
    coord = pos_dtype_for(cap) if with_positions else None
    seg_plan = _segment_plan(segment_size, c)
    m_seg = segment_size // c
    l1_len = plan.level_lens[1]
    inf = jnp.array(jnp.inf, probe.dtype)

    nseg = -(-cap // segment_size)
    base_parts, v_parts, p_parts = [], [], []
    for i in range(nseg):
        s0 = i * segment_size
        stop = min(s0 + segment_size, n)
        if i == 0:
            seg = probe
        elif s0 < n:
            seg = _read_segment(source, s0, stop)
        else:
            seg = jnp.full((0,), jnp.inf, probe.dtype)
        seg = _pad_to(seg.astype(probe.dtype), segment_size, inf)
        h_seg = build_hierarchy_fused(
            seg, seg_plan, with_positions=with_positions,
            interpret=interpret,
        )
        base_parts.append(h_seg.base)
        v_parts.append(h_seg.upper[:m_seg])
        if with_positions:
            # Segment positions are slab-local int32; globalize in the
            # coordinate dtype BEFORE offsetting (no int32 wrap).
            p_parts.append(h_seg.upper_pos[:m_seg].astype(coord) + s0)

    base = jnp.concatenate(base_parts)[:cap]
    l1_vals = jnp.concatenate(v_parts)[:l1_len]
    l1_pos = (
        jnp.concatenate(p_parts)[:l1_len] if with_positions else None
    )
    return _finish_from_level1(base, l1_vals, l1_pos, plan, with_positions)
