"""Jitted wrappers: full multi-level hierarchy updates via Pallas.

Mirrors ``repro.streaming.updates`` (the oracle) exactly — same last-wins
base scatter, same chunk dedupe — swapping only the per-level chunk
re-reduction for the scalar-prefetch Pallas kernel.  Tests assert
bit-identical hierarchies from both paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hierarchy import Hierarchy, _pad_to, pos_dtype_for
from repro.core.plan import HierarchyPlan
from repro.kernels import profiling
from repro.kernels.hierarchy_update import kernel as K
from repro.streaming.updates import scatter_base, touched_chunk_ids

__all__ = ["update_hierarchy_pallas", "append_hierarchy_pallas"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _propagate_pallas(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos,
    idxs: jax.Array,
    interpret: bool,
):
    c = plan.c
    cap = plan.capacity
    track = upper_pos is not None
    idxs = idxs.astype(jnp.int32)
    # Same out-of-range sanitization as the pure-JAX oracle: dropped
    # writes must not re-reduce (and clamp-scatter over) foreign chunks.
    idxs = jnp.where((idxs >= 0) & (idxs < cap), idxs, 0)
    ids = idxs // c
    for level in range(1, plan.num_levels):
        ids = touched_chunk_ids(ids, plan.level_lens[level])
        src_len = (plan.level_lens[1] * c if level == 1
                   else plan.level_slice(level - 1)[1])
        profiling.record_launch(
            "hierarchy_update",
            lowering="pallas",
            level=level,
            touched=int(ids.shape[0]),
            with_positions=bool(track),
            operand_bytes=(src_len * base.dtype.itemsize
                           + profiling.operand_bytes(ids)),
        )
        if level == 1:
            # Level 0 is capacity-long; align it to the chunk grid so the
            # kernel's block DMA stays in range.
            src = _pad_to(
                base, plan.level_lens[1] * c,
                jnp.array(jnp.inf, base.dtype),
            )
            if track:
                nv, np_ = K.update_level0_with_positions(
                    src, ids, c=c, cap=cap,
                    pos_dtype=pos_dtype_for(cap), interpret=interpret,
                )
            else:
                nv = K.update_level(src, ids, c=c, interpret=interpret)
                np_ = None
        else:
            off, padded = plan.level_slice(level - 1)
            src = jax.lax.slice(upper, (off,), (off + padded,))
            if track:
                src_p = jax.lax.slice(upper_pos, (off,), (off + padded,))
                nv, np_ = K.update_level_with_positions(
                    src, src_p, ids, c=c, interpret=interpret
                )
            else:
                nv = K.update_level(src, ids, c=c, interpret=interpret)
                np_ = None
        off_out = plan.offsets[level - 1]
        upper = upper.at[off_out + ids].set(nv)
        if track:
            upper_pos = upper_pos.at[off_out + ids].set(np_)
        ids = ids // c
    return upper, upper_pos


@functools.partial(jax.jit, static_argnames=("interpret",))
def _update_jit(h, idxs, vals, interpret):
    idxs = idxs.astype(jnp.int32)
    base = scatter_base(h.base, idxs, vals)
    upper, upper_pos = _propagate_pallas(
        h.plan, base, h.upper, h.upper_pos, idxs, interpret
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos,
                     plan=h.plan)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _append_jit(h, vals, start, interpret):
    vals = vals.astype(h.base.dtype)
    start = jnp.asarray(start, jnp.int32)
    base = jax.lax.dynamic_update_slice(h.base, vals, (start,))
    idxs = start + jnp.arange(vals.shape[0], dtype=jnp.int32)
    upper, upper_pos = _propagate_pallas(
        h.plan, base, h.upper, h.upper_pos, idxs, interpret
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos,
                     plan=h.plan)


def _jax_path_only(h: Hierarchy) -> bool:
    """Layouts the per-level kernel cannot re-reduce in place.

    Packed planes store chunk-local bit fields (the kernel writes
    absolute positions) and bf16 summaries need the exact level-0
    recompare; both route through the pure-JAX oracle, which handles
    them natively — same bit-identical contract, different lowering.
    """
    return bool(h.plan.packed_pos) or h.upper.dtype != h.base.dtype


def update_hierarchy_pallas(
    h: Hierarchy,
    idxs: jax.Array,
    vals: jax.Array,
    interpret: bool = None,
) -> Hierarchy:
    """Batched point updates with Pallas chunk re-reductions."""
    from repro.core.protocol import check_capacity_limit

    # The level-0 kernel synthesizes absolute positions in int32; larger
    # capacities must use the pure-JAX update path (x64).
    check_capacity_limit(h.plan.capacity)
    if _jax_path_only(h):
        from repro.streaming import updates as U

        return U.update_hierarchy(h, idxs, vals)
    if interpret is None:
        interpret = not _on_tpu()
    return _update_jit(h, idxs, vals, interpret)


def append_hierarchy_pallas(
    h: Hierarchy,
    vals: jax.Array,
    start,
    interpret: bool = None,
) -> Hierarchy:
    """Append ``vals`` at ``start`` with Pallas chunk re-reductions."""
    from repro.core.protocol import check_capacity_limit

    check_capacity_limit(h.plan.capacity)
    if _jax_path_only(h):
        from repro.streaming import updates as U

        return U.append_hierarchy(h, vals, start)
    if interpret is None:
        interpret = not _on_tpu()
    return _append_jit(h, vals, start, interpret)
