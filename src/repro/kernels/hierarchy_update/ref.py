"""Pure-jnp oracles for the hierarchy-update kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.constants import PAD_POS as _PAD_POS


def update_level_ref(values: jax.Array, ids: jax.Array, c: int) -> jax.Array:
    """Minima of chunks ``ids`` of a level padded to a multiple of c."""
    assert values.shape[0] % c == 0
    return values.reshape(-1, c)[ids].min(axis=1)


def update_level_with_positions_ref(values, positions, ids, c: int):
    assert values.shape[0] % c == 0
    v = values.reshape(-1, c)[ids]
    p = positions.reshape(-1, c)[ids]
    am = jnp.argmin(v, axis=1)
    return (
        jnp.take_along_axis(v, am[:, None], axis=1)[:, 0],
        jnp.take_along_axis(p, am[:, None], axis=1)[:, 0],
    )


def update_level0_with_positions_ref(values, ids, c: int, cap: int,
                                     pos_dtype=jnp.int32):
    """Level-1 repair oracle: positions are absolute indices (< cap)."""
    assert values.shape[0] % c == 0
    v = values.reshape(-1, c)[ids]
    idx = ids[:, None] * c + jnp.arange(c, dtype=jnp.int32)[None, :]
    p = jnp.where(idx < cap, idx, _PAD_POS).astype(pos_dtype)
    am = jnp.argmin(v, axis=1)
    return (
        jnp.take_along_axis(v, am[:, None], axis=1)[:, 0],
        jnp.take_along_axis(p, am[:, None], axis=1)[:, 0],
    )
