"""Pallas TPU kernel: scattered chunk re-reduction for hierarchy updates.

A streaming update touches an arbitrary *set* of chunks per level (the
deduped ``idx // c**k`` of the update batch).  Each grid step repairs one
touched chunk: the chunk id arrives via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), the input ``BlockSpec`` index_map uses
it to DMA exactly that ``c``-wide slice of the source level HBM→VMEM, and
the VPU re-reduces it to a single summary — the update-time mirror of the
``hierarchy_build`` kernel, which walks chunks densely.

Tie-breaking note: the position output is computed as
``min(pos where value == min)`` rather than ``pos[argmin]``.  Within a
chunk, carried positions are strictly increasing across non-padding
entries (each entry summarizes an earlier subtree than its right
neighbour) and padding positions are ``INT32_MAX``, so the two forms agree
bit-exactly with the leftmost-argmin oracle while avoiding a dynamic
gather in the kernel.

Layout notes:
* ``c >= 128`` keeps each DMA a whole lane row; smaller ``c`` works (and
  is exercised in interpret mode) but underfills the VPU on hardware.
* VMEM working set is one ``(c,)`` value slice (plus positions), far
  under budget; the win over the dense build kernel is that only touched
  chunks move through VMEM at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import PAD_POS as _PAD_POS


def _min_kernel(ids_ref, x_ref, o_ref):
    del ids_ref  # consumed by the index_map
    o_ref[0] = jnp.min(x_ref[...])


def _argmin_kernel(ids_ref, x_ref, p_ref, o_ref, po_ref):
    del ids_ref
    x = x_ref[...]
    p = p_ref[...]
    m = jnp.min(x)
    o_ref[0] = m
    po_ref[0] = jnp.min(jnp.where(x == m, p, _PAD_POS)).astype(p.dtype)


def _argmin_level0_kernel(ids_ref, x_ref, o_ref, po_ref, *, c: int,
                          cap: int, pos_dtype):
    # Level 0 carries no position array — positions are the absolute
    # indices, synthesized from the prefetched chunk id (+inf padding
    # beyond capacity gets the _PAD_POS sentinel, as in the build).
    chunk = ids_ref[pl.program_id(0)]
    x = x_ref[...].reshape(1, c)
    idx = chunk * c + jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    p = jnp.where(idx < cap, idx, _PAD_POS).astype(pos_dtype)
    m = jnp.min(x)
    o_ref[0] = m
    po_ref[0] = jnp.min(jnp.where(x == m, p, _PAD_POS)).astype(pos_dtype)


@functools.partial(jax.jit, static_argnames=("c", "interpret"))
def update_level(
    values: jax.Array,
    ids: jax.Array,
    c: int,
    interpret: bool = False,
) -> jax.Array:
    """Re-reduce chunks ``ids`` of a level: gather + min, ``(B,)`` out.

    ``values`` is the full source level, padded to a multiple of ``c``
    (ops.py pads with +inf).  ``ids`` are chunk indices into it.
    """
    assert values.shape[0] % c == 0, (values.shape, c)
    b = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[pl.BlockSpec((c,), lambda i, ids: (ids[i],))],
        out_specs=pl.BlockSpec((1,), lambda i, ids: (i,)),
    )
    return pl.pallas_call(
        _min_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b,), values.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), values)


@functools.partial(jax.jit, static_argnames=("c", "interpret"))
def update_level_with_positions(
    values: jax.Array,
    positions: jax.Array,
    ids: jax.Array,
    c: int,
    interpret: bool = False,
):
    """Chunk re-reduction carrying original-array positions (upper levels)."""
    assert values.shape[0] % c == 0, (values.shape, c)
    b = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((c,), lambda i, ids: (ids[i],)),
            pl.BlockSpec((c,), lambda i, ids: (ids[i],)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, ids: (i,)),
            pl.BlockSpec((1,), lambda i, ids: (i,)),
        ],
    )
    return pl.pallas_call(
        _argmin_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b,), values.dtype),
            jax.ShapeDtypeStruct((b,), positions.dtype),
        ],
        interpret=interpret,
    )(ids.astype(jnp.int32), values, positions)


@functools.partial(
    jax.jit, static_argnames=("c", "cap", "pos_dtype", "interpret")
)
def update_level0_with_positions(
    values: jax.Array,
    ids: jax.Array,
    c: int,
    cap: int,
    pos_dtype,
    interpret: bool = False,
):
    """Level-1 repair from level 0: positions synthesized from chunk ids."""
    assert values.shape[0] % c == 0, (values.shape, c)
    b = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[pl.BlockSpec((c,), lambda i, ids: (ids[i],))],
        out_specs=[
            pl.BlockSpec((1,), lambda i, ids: (i,)),
            pl.BlockSpec((1,), lambda i, ids: (i,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _argmin_level0_kernel, c=c, cap=cap,
            pos_dtype=jnp.dtype(pos_dtype),
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b,), values.dtype),
            jax.ShapeDtypeStruct((b,), jnp.dtype(pos_dtype)),
        ],
        interpret=interpret,
    )(ids.astype(jnp.int32), values)
