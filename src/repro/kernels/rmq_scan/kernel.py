"""Pallas TPU kernel: batched hierarchical RMQ queries (paper §4.2–§4.3).

TPU adaptation of the paper's coalesced-loading (CL) scan + warp-local
queuing (WLQ):

* **Query-tile staging (WLQ analogue).** Each program owns a tile of
  ``QUERY_BLOCK`` queries whose bounds arrive in SMEM via one block DMA —
  the analogue of WLQ's "load bounds once, recirculate through the group"
  (multi-load, the unoptimized strategy, is ``QUERY_BLOCK=1``: one program
  and one bounds transfer per query).
* **Chunk-aligned windows (CL analogue).** Every level access reads one
  aligned ``c``-wide chunk — the paper's "random but cache-aligned chunk
  accesses".  Upper levels are stored ``(rows, c)`` so a chunk is exactly
  one sublane row; level 0 chunks are DMA'd HBM→VMEM per query (the GPU's
  coalesced global load becomes an explicit DMA).
* **VMEM-resident upper levels (L2 analogue).** The whole upper buffer is
  a single VMEM block with a constant index_map, fetched once and reused
  by every grid step — the role the 100 MB L2 plays in the paper's
  profiling (§5.8: upper levels are cache-resident, so large and small
  queries cost alike).
* **Branch-free level walk (TPU-specific change).** The paper's early
  break (``r - l <= 2c``) is replaced by masks that go empty once the
  remaining range collapses: on a GPU the break saves divergent work; on
  the VPU a fixed-shape masked scan is cheaper than control flow.  Cost
  per query is a *constant* ``2c·(L-1) + c·t`` lanes regardless of range
  size — the extreme version of the paper's Fig. 16 observation that
  GPU-RMQ's latency is nearly range-size independent.
  Correctness of the overlap case (range inside one chunk): the two
  boundary masks may cover the same entries — min is idempotent, and the
  (value, leftmost-pos) merge is associative/commutative/idempotent too.

Index math invariants (with ``r`` exclusive):
  left window anchor  = floor(l / c) * c      (covers [l, min(ceil(l/c)*c, r)))
  right window anchor = floor(r / c) * c      (covers [max(anchor, l), r))
  ascend:  l' = ceil(l / c), r' = floor(r / c)   (empty ranges stay empty)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import HierarchyPlan

from repro.core.constants import POS_INF_I32 as _POS_INF_I32

DEFAULT_QUERY_BLOCK = 256


def _masked_min_2d(vals, idx, lo, hi, pos=None):
    """(min, leftmost-pos) over ``vals`` where ``lo <= idx < hi``.

    ``vals``/``idx``/``pos`` are (rows, c); returns two scalars.
    """
    inf = jnp.array(jnp.inf, dtype=vals.dtype)
    mask = (idx >= lo) & (idx < hi)
    masked = jnp.where(mask, vals, inf)
    m = jnp.min(masked)
    if pos is None:
        return m, jnp.int32(_POS_INF_I32)
    cand = jnp.where(mask & (masked == m), pos, _POS_INF_I32)
    return m, jnp.min(cand)


def _merge(m, p, m2, p2):
    take2 = (m2 < m) | ((m2 == m) & (p2 < p))
    return jnp.where(take2, m2, m), jnp.where(take2, p2, p)


def _rmq_query_kernel(
    # inputs
    l_ref,          # SMEM (qb,) i32
    r_ref,          # SMEM (qb,) i32
    base_hbm,       # ANY  (n,)  values, stays in HBM
    upper_ref,      # VMEM (rows, c) all upper levels, chunk per row
    upper_pos_ref,  # VMEM (rows, c) i32 or None (closure decides)
    # outputs
    out_ref,        # SMEM (qb,) f32
    out_pos_ref,    # SMEM (qb,) i32 or None
    # scratch
    win_ref,        # VMEM (2, 2, c) double-buffered boundary windows
    sems,           # DMA semaphores (2, 2)
    *,
    plan: HierarchyPlan,
    qb: int,
    track_pos: bool,
):
    c = plan.c
    n = plan.capacity  # stored base length (== n unless capacity reserved)
    num_levels = plan.num_levels
    inf = jnp.float32(jnp.inf)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)

    def window_starts(i):
        """Aligned level-0 window anchors for query i."""
        l = l_ref[i]
        r = r_ref[i] + 1
        a_start = jnp.clip((l // c) * c, 0, max(n - c, 0))
        b_start = jnp.clip(((r // c) * c), 0, max(n - c, 0))
        return a_start, b_start

    def issue(i, slot):
        """Start both boundary-window DMAs for query i into buffer slot."""
        a_start, b_start = window_starts(i)
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(a_start, c)], win_ref.at[slot, 0],
            sems.at[slot, 0],
        ).start()
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(b_start, c)], win_ref.at[slot, 1],
            sems.at[slot, 1],
        ).start()

    def wait(i, slot):
        a_start, b_start = window_starts(i)
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(a_start, c)], win_ref.at[slot, 0],
            sems.at[slot, 0],
        ).wait()
        pltpu.make_async_copy(
            base_hbm.at[pl.ds(b_start, c)], win_ref.at[slot, 1],
            sems.at[slot, 1],
        ).wait()

    # ---- software pipeline: prefetch query i+1's level-0 windows while
    # the VPU scans query i (DESIGN.md §2.1 — the DMA engines play the
    # role of the paper's "other compute unit"; this is the overlap
    # insight of the RT-core hybrid, realized with TPU-native hardware).
    issue(0, 0)

    def body(i, _):
        slot = jax.lax.rem(i, 2)
        wait(i, slot)

        @pl.when(i + 1 < qb)
        def _prefetch():
            issue(i + 1, 1 - slot)

        l = l_ref[i]
        r = r_ref[i] + 1  # exclusive
        a_start, b_start = window_starts(i)

        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c

        idx_a = a_start + lane
        idx_b = b_start + lane
        pos_a = idx_a if track_pos else None
        pos_b = idx_b if track_pos else None
        m, p = _masked_min_2d(
            win_ref[slot, 0].reshape(1, c), idx_a, l,
            jnp.minimum(next_l, r), pos_a,
        )
        m2, p2 = _masked_min_2d(
            win_ref[slot, 1].reshape(1, c), idx_b,
            jnp.maximum(prev_r, l), r, pos_b,
        )
        m, p = _merge(m, p, m2, p2)

        l_k = (l + c - 1) // c   # ceil
        r_k = r // c             # floor

        # ---- upper levels: aligned single-row loads from VMEM ----------
        for level in range(1, num_levels):
            off_rows = plan.offsets[level - 1] // c
            padded_rows = plan.padded_lens[level - 1] // c
            is_last = level == num_levels - 1
            if is_last:
                # static full-top masked scan
                rows = padded_rows
                vals = upper_ref[off_rows : off_rows + rows, :]
                idx = (
                    jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) * c
                    + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
                )
                pos = (
                    upper_pos_ref[off_rows : off_rows + rows, :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(vals, idx, l_k, r_k, pos)
                m, p = _merge(m, p, m2, p2)
            else:
                a_row = jnp.clip(l_k // c, 0, padded_rows - 1)
                b_row = jnp.clip(r_k // c, 0, padded_rows - 1)
                nl = ((l_k + c - 1) // c) * c
                pr = (r_k // c) * c
                va = upper_ref[pl.ds(off_rows + a_row, 1), :]
                vb = upper_ref[pl.ds(off_rows + b_row, 1), :]
                ia = a_row * c + lane
                ib = b_row * c + lane
                pa = (
                    upper_pos_ref[pl.ds(off_rows + a_row, 1), :]
                    if track_pos
                    else None
                )
                pb = (
                    upper_pos_ref[pl.ds(off_rows + b_row, 1), :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(va, ia, l_k, jnp.minimum(nl, r_k), pa)
                m, p = _merge(m, p, m2, p2)
                m2, p2 = _masked_min_2d(vb, ib, jnp.maximum(pr, l_k), r_k, pb)
                m, p = _merge(m, p, m2, p2)
                l_k = (l_k + c - 1) // c
                r_k = r_k // c

        out_ref[i] = m
        if track_pos:
            out_pos_ref[i] = p
        return 0

    jax.lax.fori_loop(0, qb, body, 0)


def rmq_query_pallas(
    base: jax.Array,
    upper2d: jax.Array,
    upper_pos2d: Optional[jax.Array],
    ls: jax.Array,
    rs: jax.Array,
    plan: HierarchyPlan,
    qb: int = DEFAULT_QUERY_BLOCK,
    track_pos: bool = False,
    interpret: bool = False,
):
    """Launch the query kernel.  ``ls.shape[0]`` must be a multiple of qb.

    ``upper2d`` is the contiguous upper buffer reshaped to ``(rows, c)``
    (one chunk per sublane row).  Returns ``(values, positions)``;
    positions are INT32_MAX when ``track_pos=False``.
    """
    m = ls.shape[0]
    assert m % qb == 0, (m, qb)
    grid = (m // qb,)
    rows = upper2d.shape[0]
    c = plan.c

    kernel = functools.partial(
        _rmq_query_kernel, plan=plan, qb=qb, track_pos=track_pos
    )

    in_specs = [
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pl.ANY),          # base stays in HBM
        pl.BlockSpec((rows, c), lambda i: (0, 0)),     # upper: whole, reused
    ]
    out_specs = [
        pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM),
    ]
    out_shape = [jax.ShapeDtypeStruct((m,), base.dtype)]

    if track_pos:
        in_specs.append(pl.BlockSpec((rows, c), lambda i: (0, 0)))
        out_specs.append(
            pl.BlockSpec((qb,), lambda i: (i,), memory_space=pltpu.SMEM)
        )
        out_shape.append(jax.ShapeDtypeStruct((m,), jnp.int32))
        args = (ls, rs, base, upper2d, upper_pos2d)

        def kern(l_ref, r_ref, base_h, up_ref, upos_ref, o_ref, opos_ref,
                 win, sems):
            kernel(l_ref, r_ref, base_h, up_ref, upos_ref, o_ref, opos_ref,
                   win, sems)
    else:
        args = (ls, rs, base, upper2d)

        def kern(l_ref, r_ref, base_h, up_ref, o_ref, win, sems):
            kernel(l_ref, r_ref, base_h, up_ref, None, o_ref, None,
                   win, sems)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((2, 2, c), base.dtype),   # [slot][side][c] dbl-buf
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(*args)
    if track_pos:
        return out[0], out[1]
    return out[0], None
