"""Jitted wrappers for the Pallas RMQ query kernel.

Handles: query-batch padding to the query block, the (rows, c) view of the
upper buffer, backend fallbacks (single-level plans and n < c degenerate
cases use the pure-JAX core path — they have no hierarchy to exploit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.hierarchy import Hierarchy
from repro.core.query import rmq_index_batch, rmq_value_batch
from repro.kernels import profiling
from repro.kernels.rmq_scan import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_applicable(h: Hierarchy) -> bool:
    return h.plan.num_levels >= 2 and h.plan.n >= h.plan.c


@functools.partial(
    jax.jit,
    static_argnames=("plan", "qb", "track_pos", "interpret"),
)
def _run(base, upper, upper_pos, ls, rs, plan, qb, track_pos, interpret):
    m = ls.shape[0]
    m_pad = -(-m // qb) * qb
    profiling.record_launch(
        "rmq_scan",
        lowering="pallas",
        queries=int(m),
        grid=int(m_pad // qb),
        levels=plan.num_levels,
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(
            base, upper, upper_pos, ls, rs),
    )
    if m_pad != m:
        ls = jnp.pad(ls, (0, m_pad - m))
        rs = jnp.pad(rs, (0, m_pad - m))
    # Packed planes unpack to absolute positions inside this same
    # program; the kernel always consumes the classic (rows, c) layout.
    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    upper2d = upper.reshape(-1, plan.c)
    upos2d = (
        upper_pos.reshape(-1, plan.c) if track_pos else None
    )
    vals, pos = K.rmq_query_pallas(
        base,
        upper2d,
        upos2d,
        ls.astype(jnp.int32),
        rs.astype(jnp.int32),
        plan,
        qb=qb,
        track_pos=track_pos,
        interpret=interpret,
    )
    if track_pos:
        return vals[:m], pos[:m]
    return vals[:m], None


def rmq_value_batch_pallas(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    if not _kernel_applicable(h) or h.upper.dtype != h.base.dtype:
        # bf16 summaries need the exact-recovery walk; the scan kernel
        # compares quantized values only.
        return rmq_value_batch(h, ls, rs)
    if interpret is None:
        interpret = not _on_tpu()
    vals, _ = _run(
        h.base, h.upper, None, ls, rs, h.plan, qb, False, interpret
    )
    return vals


def rmq_index_batch_pallas(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    if not h.with_positions:
        raise ValueError("hierarchy built without positions")
    if not _kernel_applicable(h) or h.upper.dtype != h.base.dtype:
        return rmq_index_batch(h, ls, rs)
    if interpret is None:
        interpret = not _on_tpu()
    _, pos = _run(
        h.base, h.upper, h.upper_pos, ls, rs, h.plan, qb, True, interpret
    )
    return pos
