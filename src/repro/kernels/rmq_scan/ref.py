"""Pure-jnp oracle for the RMQ query kernel.

The production pure-JAX path (``repro.core.query``) implements the paper's
Listing 2 with the data-dependent early break; the kernel uses the
branch-free walk (see kernel.py docstring).  This oracle implements the
*branch-free* recurrence in plain jnp so kernel tests can localize a
divergence to either (a) branch-free algebra (oracle vs core) or (b) the
Pallas lowering (kernel vs oracle).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.constants import POS_INF_I32 as _POS_INF_I32
from repro.core.plan import HierarchyPlan


def _merge(m, p, m2, p2):
    take2 = (m2 < m) | ((m2 == m) & (p2 < p))
    return jnp.where(take2, m2, m), jnp.where(take2, p2, p)


def _window(arr, pos_arr, anchor, lo, hi, c, track_pos):
    n = arr.shape[0]
    start = jnp.clip(anchor, 0, max(n - c, 0))
    vals = jax.lax.dynamic_slice(arr, (start,), (c,))
    idx = start + jnp.arange(c, dtype=jnp.int32)
    mask = (idx >= lo) & (idx < hi)
    masked = jnp.where(mask, vals, jnp.inf)
    m = jnp.min(masked)
    if not track_pos:
        return m, jnp.int32(_POS_INF_I32)
    pos = idx if pos_arr is None else jax.lax.dynamic_slice(
        pos_arr, (start,), (c,)
    )
    cand = jnp.where(mask & (masked == m), pos, _POS_INF_I32)
    return m, jnp.min(cand)


def rmq_branchfree_single(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos: Optional[jax.Array],
    l: jax.Array,
    r: jax.Array,
    track_pos: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Branch-free hierarchical RMQ (kernel algorithm, plain jnp)."""
    c = plan.c
    l = l.astype(jnp.int32)
    r = (r + 1).astype(jnp.int32)
    m = jnp.float32(jnp.inf)
    p = jnp.int32(_POS_INF_I32)

    def level_arrays(level):
        if level == 0:
            return base, None
        off, padded = plan.level_slice(level)
        vals = jax.lax.slice(upper, (off,), (off + padded,))
        pos = (
            None
            if upper_pos is None
            else jax.lax.slice(upper_pos, (off,), (off + padded,))
        )
        return vals, pos

    for level in range(plan.num_levels):
        arr, pos_arr = level_arrays(level)
        is_last = level == plan.num_levels - 1
        if is_last:
            idx = jnp.arange(arr.shape[0], dtype=jnp.int32)
            mask = (idx >= l) & (idx < r)
            masked = jnp.where(mask, arr, jnp.inf)
            m2 = jnp.min(masked)
            if track_pos:
                pos = idx if pos_arr is None else pos_arr
                cand = jnp.where(mask & (masked == m2), pos, _POS_INF_I32)
                p2 = jnp.min(cand)
            else:
                p2 = jnp.int32(_POS_INF_I32)
            m, p = _merge(m, p, m2, p2)
            break

        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c
        m2, p2 = _window(
            arr, pos_arr, (l // c) * c, l, jnp.minimum(next_l, r), c,
            track_pos,
        )
        m, p = _merge(m, p, m2, p2)
        m2, p2 = _window(
            arr, pos_arr, prev_r, jnp.maximum(prev_r, l), r, c, track_pos
        )
        m, p = _merge(m, p, m2, p2)
        l = (l + c - 1) // c
        r = r // c

    return m, p


def rmq_branchfree_batch(plan, base, upper, upper_pos, ls, rs,
                         track_pos=False):
    return jax.vmap(
        lambda l, r: rmq_branchfree_single(
            plan, base, upper, upper_pos, l, r, track_pos
        )
    )(ls, rs)
