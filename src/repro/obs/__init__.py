"""repro.obs — unified observability: tracing, metrics, launch registry.

Three surfaces, one import point:

* :mod:`repro.obs.trace` — request-lifecycle spans
  (``submit → admission → queue → snapshot_swap → plan → execute →
  scatter``) with Chrome-trace/Perfetto export;
* :mod:`repro.obs.metrics` — counters/gauges/histograms with dict and
  Prometheus text exposition (promoted from ``repro.serving.metrics``);
* :mod:`repro.kernels.profiling` — the kernel launch/cost registry
  (lives next to the kernels it instruments; re-exported here).

All three follow the same discipline: a single module-global check on
the hot path, zero locks and zero allocations when disabled.
"""

from repro.kernels.profiling import (
    LaunchRecord,
    LaunchRegistry,
    count_launches,
    launch_registry,
    record_launch,
)
from repro.obs.metrics import (
    LATENCY_BUCKETS,
    SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Metrics,
)
from repro.obs.trace import Span, Tracer, set_tracer, use_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS",
    "LaunchRecord",
    "LaunchRegistry",
    "Metrics",
    "SIZE_BUCKETS",
    "Span",
    "Tracer",
    "count_launches",
    "launch_registry",
    "record_launch",
    "set_tracer",
    "use_tracer",
]
