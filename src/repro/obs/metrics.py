"""Unified metrics: counters, gauges, histograms, dict + Prometheus export.

Promoted from ``repro.serving.metrics`` (which remains as a re-export
shim) so the engine, service, and serving tier share one registry tree.
Deliberately dependency-free (no prometheus client in the container):
monotonic :class:`Counter`\\ s, read-through :class:`Gauge`\\ s and
fixed-bucket :class:`Histogram`\\ s collected in a :class:`Metrics`
registry. :meth:`Metrics.as_dict` emits a plain nested dict — the
exchange format tests, benchmarks and examples consume directly — and
:meth:`Metrics.to_prometheus` emits the text exposition format a
production scrape endpoint would serve.

Everything mutable is lock-protected: the tier's flusher thread and
caller threads record concurrently (``x += 1`` on an attribute is NOT
atomic under the GIL).  Gauges may instead wrap a zero-argument callback
(``gauge("hit_rate", fn=...)``) so hot paths keep their plain-int
counters and pay the read cost only at export time.

Registries nest: ``metrics.scope("tenants").scope("search")`` gives each
tenant its own namespace inside one exported tree.  A scope created with
``child_label`` renders its child scopes as Prometheus *label values*
rather than name segments — ``scope("tenants", child_label="tenant")``
exports ``repro_tenants_submits_total{tenant="search"}``.  Metric
objects are created lazily on first touch and are stable thereafter, so
hot paths can hold a reference
(``self._submits = scope.counter("submits")``) instead of re-resolving
names per call.
"""

from __future__ import annotations

import re
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["Counter", "Gauge", "Histogram", "Info", "Metrics",
           "LATENCY_BUCKETS", "SIZE_BUCKETS"]

# Log-spaced seconds from 10us to ~10s — spans a sub-millisecond SLO and
# a pathological multi-second stall in the same histogram.
LATENCY_BUCKETS = tuple(1e-5 * (10 ** (i / 3.0)) for i in range(19))

# Pow2 batch/queue-depth buckets up to the fused bucket ceiling.
SIZE_BUCKETS = tuple(float(1 << i) for i in range(15))


class Counter:
    """Monotonic counter (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def as_dict(self) -> int:
        return self._value


class Gauge:
    """Point-in-time value: either set explicitly or read through a
    zero-argument callback.

    The callback form is the cheap way to export state a hot path
    already tracks as plain attributes (cache hit counts, queue depth):
    nothing is double-booked per operation, the source is read once per
    export.  A callback that raises exports 0.0 rather than poisoning
    the whole scrape.
    """

    __slots__ = ("_lock", "_value", "_fn")

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            self._fn = None

    def set_fn(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    @property
    def value(self) -> float:
        fn = self._fn
        if fn is not None:
            try:
                return float(fn())
            except Exception:
                return 0.0
        return self._value

    def as_dict(self) -> float:
        return self.value


class Info:
    """The Prometheus *info* pattern: a constant-``1`` gauge whose
    **labels** carry the payload (build/version/config facts that are
    strings, not numbers) — e.g.
    ``repro_engine_tuned_config{c="128",backend="fused",...} 1``.

    :meth:`set` replaces the whole label set atomically; exporting an
    Info that was never set emits nothing (no labels to report).
    """

    __slots__ = ("_lock", "_labels")

    def __init__(self):
        self._lock = threading.Lock()
        self._labels: Dict[str, str] = {}

    def set(self, labels: Optional[Dict[str, str]]) -> None:
        labels = {str(k): str(v) for k, v in dict(labels or {}).items()}
        with self._lock:
            self._labels = labels

    @property
    def labels(self) -> Dict[str, str]:
        with self._lock:
            return dict(self._labels)

    def as_dict(self) -> Dict[str, str]:
        return self.labels


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max + bucket percentiles.

    ``bounds`` are bucket *upper* edges; an implicit +inf bucket catches
    the overflow.  :meth:`percentile` answers from bucket edges (clamped
    to the observed max), so it is a bounded-error estimate — callers
    needing exact tail latencies keep their own sample list and use this
    for the exported summary.
    """

    __slots__ = ("_lock", "bounds", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS):
        self._lock = threading.Lock()
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def record(self, value: float) -> None:
        value = float(value)
        lo, hi = 0, len(self.bounds)
        while lo < hi:                      # first bucket with bound >= value
            mid = (lo + hi) // 2
            if value <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self.counts[lo] += 1
            self.count += 1
            self.total += value
            self.vmin = min(self.vmin, value)
            self.vmax = max(self.vmax, value)

    def snapshot(self) -> Tuple[List[int], int, float, float, float]:
        """One consistent ``(counts, count, total, vmin, vmax)`` read.

        Everything derived (percentiles, means, exports) starts from a
        snapshot so a concurrent :meth:`record` can never be observed
        half-applied (count bumped but total not yet, etc.).
        """
        with self._lock:
            return (list(self.counts), self.count, self.total,
                    self.vmin, self.vmax)

    def _percentile_from(self, snap, q: float) -> float:
        counts, count, _total, _vmin, vmax = snap
        if count == 0:
            return 0.0
        rank = q * count
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank and c:
                edge = self.bounds[i] if i < len(self.bounds) else vmax
                return min(edge, vmax)
        return vmax

    def percentile(self, q: float) -> float:
        """Upper-edge estimate of the ``q``-quantile (q in [0, 1])."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        return self._percentile_from(self.snapshot(), q)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        # One snapshot for every field: the previous implementation
        # released the lock after the count==0 check and re-read live
        # attributes, so a concurrent record() could produce a dict
        # where e.g. count was bumped but sum was not.
        snap = self.snapshot()
        counts, count, total, vmin, vmax = snap
        if count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p99": 0.0}
        return {
            "count": count,
            "sum": total,
            "min": vmin,
            "max": vmax,
            "mean": total / count,
            "p50": self._percentile_from(snap, 0.50),
            "p99": self._percentile_from(snap, 0.99),
        }


_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(*parts: str) -> str:
    name = "_".join(p for p in parts if p)
    name = _NAME_SANITIZE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _prom_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    items = []
    for k, v in labels.items():
        v = str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")
        items.append(f'{_NAME_SANITIZE.sub("_", k)}="{v}"')
    return "{" + ",".join(items) + "}"


def _prom_float(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


class Metrics:
    """Lazy registry of named counters/gauges/histograms + nested scopes."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[
            str, Union[Counter, Gauge, Histogram, Info]] = {}
        self._scopes: Dict[str, "Metrics"] = {}
        self._child_label: Optional[str] = None

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, ())

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(name, Gauge, ())
        if fn is not None:
            g.set_fn(fn)
        return g

    def histogram(
        self, name: str, bounds: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get(name, Histogram,
                         (bounds if bounds is not None else LATENCY_BUCKETS,))

    def info(self, name: str,
             labels: Optional[Dict[str, str]] = None) -> Info:
        """Constant-1 gauge whose labels carry string facts (see
        :class:`Info`)."""
        m = self._get(name, Info, ())
        if labels is not None:
            m.set(labels)
        return m

    def scope(self, name: str,
              child_label: Optional[str] = None) -> "Metrics":
        """Child registry.  With ``child_label``, this scope's own child
        scopes export as Prometheus label values (``{child_label="..."}``)
        instead of name segments."""
        with self._lock:
            if name in self._metrics:
                raise ValueError(f"{name!r} is already a metric here")
            scope = self._scopes.get(name)
            if scope is None:
                scope = self._scopes[name] = Metrics()
            if child_label is not None:
                scope._child_label = child_label
            return scope

    def _get(self, name, cls, args):
        with self._lock:
            if name in self._scopes:
                raise ValueError(f"{name!r} is already a scope here")
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(*args)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"{name!r} is a {type(m).__name__}, not {cls.__name__}"
                )
            return m

    def as_dict(self) -> dict:
        with self._lock:
            metrics = dict(self._metrics)
            scopes = dict(self._scopes)
        out = {name: m.as_dict() for name, m in metrics.items()}
        for name, scope in scopes.items():
            out[name] = scope.as_dict()
        return out

    # -- Prometheus text exposition -----------------------------------------
    def _samples(self, prefix: str, labels: Dict[str, str], out: list):
        """Collect (prom_name, kind, labels, payload) rows depth-first."""
        with self._lock:
            metrics = list(self._metrics.items())
            scopes = list(self._scopes.items())
            child_label = self._child_label
        for name, m in metrics:
            pname = _prom_name(prefix, name)
            if isinstance(m, Counter):
                out.append((pname + "_total", "counter", labels, m.value))
            elif isinstance(m, Gauge):
                out.append((pname, "gauge", labels, m.value))
            elif isinstance(m, Info):
                info_labels = m.labels
                if info_labels:
                    out.append(
                        (pname, "gauge", {**labels, **info_labels}, 1.0))
            else:
                out.append((pname, "histogram", labels, m))
        for name, scope in scopes:
            if child_label is not None:
                sub = dict(labels)
                sub[child_label] = name
                scope._samples(prefix, sub, out)
            else:
                scope._samples(_prom_name(prefix, name), labels, out)

    def to_prometheus(self, prefix: str = "repro") -> str:
        """Prometheus/OpenMetrics-style text exposition.

        Counters get a ``_total`` suffix; histograms expand to
        cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``;
        scopes either extend the metric name or become labels (see
        :meth:`scope`).  Ends with a trailing newline, as scrapers
        expect.
        """
        samples: list = []
        self._samples(_prom_name(prefix), {}, samples)
        typed: Dict[str, str] = {}
        order: List[str] = []
        by_name: Dict[str, list] = {}
        for pname, kind, labels, payload in samples:
            if pname not in typed:
                typed[pname] = kind
                order.append(pname)
                by_name[pname] = []
            by_name[pname].append((labels, payload))
        lines: List[str] = []
        for pname in order:
            kind = typed[pname]
            lines.append(f"# TYPE {pname} {kind}")
            for labels, payload in by_name[pname]:
                if kind == "histogram":
                    hist: Histogram = payload
                    counts, count, total, _vmin, _vmax = hist.snapshot()
                    cum = 0
                    for bound, c in zip(hist.bounds, counts):
                        cum += c
                        le = dict(labels)
                        le["le"] = _prom_float(bound)
                        lines.append(
                            f"{pname}_bucket{_prom_labels(le)} {cum}")
                    cum += counts[-1]
                    le = dict(labels)
                    le["le"] = "+Inf"
                    lines.append(f"{pname}_bucket{_prom_labels(le)} {cum}")
                    lines.append(
                        f"{pname}_sum{_prom_labels(labels)} "
                        f"{_prom_float(total if count else 0.0)}")
                    lines.append(
                        f"{pname}_count{_prom_labels(labels)} {count}")
                else:
                    lines.append(
                        f"{pname}{_prom_labels(labels)} "
                        f"{_prom_float(payload)}")
        return "\n".join(lines) + "\n"
