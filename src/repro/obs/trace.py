"""Request tracing: explicit spans, a thread-safe ring buffer, and
Chrome-trace/Perfetto export.

The serving stack's claims are *per-request* claims — one fused launch
per flush, snapshot-stable reads, SLO-bounded queueing — but until now
only aggregate counters existed to check them.  This module records the
full request lifecycle as spans::

    submit -> admission -> queue -> snapshot_swap -> plan
           -> execute(launch) -> scatter

Design constraints, in order:

* **zero cost when disabled** — instrumentation sites read one module
  global (``current()``); when no tracer is installed they take no
  locks and allocate nothing (the same discipline as
  ``repro.kernels.profiling.record_launch``).  Hot paths use the
  ``tr = current(); if tr is not None`` guard; cold paths may use the
  module-level :func:`span` helper, which returns a shared no-op
  context manager;
* **injectable clock** — defaults to ``time.monotonic`` so span
  timestamps are directly comparable with the serving tier's deadline
  clock; tests inject a fake clock and assert exact orderings;
* **thread-safe bounded buffer** — spans record from caller threads and
  the flusher thread concurrently; the buffer is a ring
  (``maxlen=capacity``) so a long-running service can leave tracing on
  without unbounded growth;
* **nesting by thread** — each thread keeps its own open-span stack
  (thread-local), so a span opened on the flusher thread can never
  adopt a caller thread's span as parent.  Cross-thread edges (the
  ``queue`` wait between a caller's submit and the flusher's drain) are
  recorded retroactively with :meth:`Tracer.record`, using timestamps
  from the shared clock.

Export: :meth:`Tracer.to_chrome_trace` emits the Chrome trace event
format (``chrome://tracing`` / Perfetto / ``ui.perfetto.dev``) — one
complete (``"ph": "X"``) event per span, microsecond timestamps, span
and parent ids in ``args`` so the tree survives tools that re-sort.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "current",
    "instant",
    "record",
    "set_tracer",
    "span",
    "use_tracer",
]


@dataclasses.dataclass
class Span:
    """One recorded (or still-open) span.  Times are clock seconds."""

    name: str
    start: float
    span_id: int
    parent_id: Optional[int]
    thread: str
    end: Optional[float] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.end if self.end is not None else self.start) - self.start


class _SpanCtx:
    """Context-manager shim over ``Tracer.begin``/``Tracer.end``."""

    __slots__ = ("_tracer", "_span", "_args")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._args = args
        self._span = tracer.begin(name)

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._span, **self._args)


class _NullSpan:
    """Shared no-op context manager: the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        return None


_NULL = _NullSpan()


class Tracer:
    """Explicit-span tracer with a bounded, thread-safe buffer.

    ``clock`` is injectable (fake clocks in tests; must match the clock
    of any timestamps passed to :meth:`record`).  ``capacity`` bounds
    the retained span count — the oldest spans fall off the ring.
    """

    def __init__(
        self,
        clock=time.monotonic,
        capacity: int = 65536,
    ):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self.dropped = 0          # spans pushed off the ring

    # -- span lifecycle ----------------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def begin(self, name: str) -> Span:
        """Open a span (child of this thread's innermost open span)."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        sp = Span(
            name=name,
            start=self._clock(),
            span_id=next(self._ids),
            parent_id=parent,
            thread=threading.current_thread().name,
        )
        stack.append(sp)
        return sp

    def end(self, sp: Span, **args) -> Span:
        """Close ``sp`` and record it.  Tolerant of unbalanced stacks
        (an exception that skipped inner ``end`` calls): closes any
        still-open descendants silently."""
        sp.end = self._clock()
        if args:
            sp.args.update(args)
        stack = self._stack()
        if sp in stack:
            del stack[stack.index(sp):]
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(sp)
        return sp

    def span(self, name: str, **args) -> _SpanCtx:
        """``with tracer.span("plan", batch=64):`` — begin/end + args."""
        return _SpanCtx(self, name, args)

    def record(
        self,
        name: str,
        start: float,
        end: float,
        parent: Optional[Span] = None,
        **args,
    ) -> Span:
        """Record a span with explicit timestamps (same clock as the
        tracer's).  This is how cross-thread waits — e.g. the ``queue``
        time between a caller's submit and the flusher's drain — enter
        the trace without holding a span open across threads."""
        sp = Span(
            name=name,
            start=start,
            span_id=next(self._ids),
            parent_id=parent.span_id if parent is not None else None,
            thread=threading.current_thread().name,
            end=end,
            args=dict(args),
        )
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(sp)
        return sp

    def instant(self, name: str, **args) -> Span:
        """Zero-duration marker event."""
        now = self._clock()
        return self.record(name, now, now, **args)

    # -- introspection / export --------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of recorded spans, in completion order."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    def to_chrome_trace(self) -> dict:
        """The Chrome trace event format (Perfetto-loadable) as a dict.

        One ``"ph": "X"`` complete event per span; ``ts``/``dur`` in
        microseconds on the tracer's clock; span/parent ids in ``args``
        so the tree is recoverable independent of nesting heuristics.
        """
        events = []
        for sp in self.spans():
            args = {"span_id": sp.span_id}
            if sp.parent_id is not None:
                args["parent_id"] = sp.parent_id
            args.update(sp.args)
            events.append({
                "name": sp.name,
                "cat": "repro",
                "ph": "X",
                "ts": sp.start * 1e6,
                "dur": max(sp.duration, 0.0) * 1e6,
                "pid": 0,
                "tid": sp.thread,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")


# ---------------------------------------------------------------------------
# the installed tracer (module global, like profiling's launch counter)
# ---------------------------------------------------------------------------
_active: Optional[Tracer] = None


def current() -> Optional[Tracer]:
    """The installed tracer, or None (tracing disabled).

    Hot paths read this once per batch and branch on ``is not None`` —
    the disabled cost is one global load, no locks, no allocations.
    """
    return _active


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or, with None, remove) the process-wide tracer.
    Returns the previously installed tracer."""
    global _active
    prev = _active
    _active = tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Tracer) -> Iterator[Tracer]:
    """Install ``tracer`` for the duration of the block."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def span(name: str, **args):
    """``with trace.span("flush", tenant=t):`` — no-op when disabled.

    Convenience for cold paths (per-flush, not per-query): when tracing
    is disabled it returns a shared null context (the ``**args`` dict is
    the only allocation).  Hot paths should use the
    ``current()``-and-guard pattern instead.
    """
    t = _active
    if t is None:
        return _NULL
    return t.span(name, **args)


def instant(name: str, **args) -> Optional[Span]:
    """Zero-duration marker; no-op when disabled."""
    t = _active
    if t is None:
        return None
    return t.instant(name, **args)


def record(name: str, start: float, end: float, **args) -> Optional[Span]:
    """Explicit-timestamp span; no-op when disabled."""
    t = _active
    if t is None:
        return None
    return t.record(name, start, end, **args)
